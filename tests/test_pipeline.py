"""Pipeline schedules: simulator invariants (Table 4), executable GPipe,
tick tables for the manual-backward runner, and ParallelPlan validation."""
import os
import subprocess
import sys
import textwrap

from _subproc import REPO_ROOT, subprocess_env

import pytest

from repro.core.pipeline import SCHEDULES, simulate, tick_table



def test_gpipe_bubble_closed_form():
    # classic GPipe bubble with t_bwd = 2*t_fwd: (P-1)*(tf+tb)/(M*(tf+tb)+(P-1)*(tf+tb))
    P, M = 4, 8
    r = simulate("gpipe", P, M, t_fwd=1.0, t_bwd=2.0)
    expect = (P - 1) * 3.0 / (M * 3.0 + (P - 1) * 3.0)
    assert r.bubble_fraction == pytest.approx(expect, abs=1e-6)


def test_1f1b_memory_better_than_gpipe():
    P, M = 4, 16
    g = simulate("gpipe", P, M)
    f = simulate("1f1b", P, M)
    assert f.peak_activations <= P  # bounded by stages, not microbatches
    assert g.peak_activations == M  # stores all microbatches
    assert f.peak_activations < g.peak_activations


def test_1f1b_same_bubble_as_gpipe():
    P, M = 4, 8
    g = simulate("gpipe", P, M)
    f = simulate("1f1b", P, M)
    assert f.bubble_fraction == pytest.approx(g.bubble_fraction, abs=0.02)


def test_interleaved_reduces_bubble():
    P, M = 4, 8
    base = simulate("1f1b", P, M)
    inter = simulate("interleaved", P, M, v=2)
    assert inter.bubble_fraction < base.bubble_fraction + 1e-9, (inter, base)


def test_async_rows_report_staleness_and_versions():
    pd = simulate("pipedream", 4, 8)
    assert not pd.synchronous and pd.weight_versions == 4 and pd.max_staleness == 3
    pd2 = simulate("pipedream_2bw", 4, 8)
    assert pd2.weight_versions == 2 and pd2.max_staleness == 1


def test_all_schedules_complete():
    for name in SCHEDULES:
        r = simulate(name, 4, 8)
        assert r.makespan > 0
        assert 0 <= r.bubble_fraction < 1


def test_more_microbatches_shrink_bubble():
    b8 = simulate("gpipe", 4, 8).bubble_fraction
    b32 = simulate("gpipe", 4, 32).bubble_fraction
    assert b32 < b8


# ---------------------------------------------------------------- tick tables
@pytest.mark.parametrize("sched", ["gpipe", "1f1b"])
@pytest.mark.parametrize("P,M", [(2, 4), (2, 8), (4, 4), (4, 16), (3, 5), (1, 4)])
def test_tick_table_matches_simulator(sched, P, M):
    """The executable table IS the simulator schedule: same bubble, and the
    greedy slot allocation reproduces Table 4's peak-activation column."""
    t = tick_table(sched, P, M)
    sim = simulate(sched, P, M, t_fwd=1.0, t_bwd=1.0)
    assert t.bubble_fraction == pytest.approx(sim.bubble_fraction, abs=1e-9)
    assert t.n_act_slots == sim.peak_activations
    # every microbatch appears exactly once as F and once as B per stage
    for s in range(P):
        assert sorted(m for m in t.f_mb[:, s] if m >= 0) == list(range(M))
        assert sorted(m for m in t.b_mb[:, s] if m >= 0) == list(range(M))


def test_tick_table_1f1b_memory_bound():
    """1F1B buffers are O(P); GPipe's are O(M) — strict gap at M >= 2P."""
    for P in (2, 4):
        M = 2 * P
        f, g = tick_table("1f1b", P, M), tick_table("gpipe", P, M)
        assert f.n_act_slots == min(P, M)
        assert g.n_act_slots == M
        assert f.peak_activation_bytes(1) < g.peak_activation_bytes(1)
        # same schedule length -> same bubble, less memory
        assert f.bubble_fraction == pytest.approx(g.bubble_fraction, abs=1e-9)


def test_tick_table_rejects_simulator_only_schedules():
    with pytest.raises(ValueError):
        tick_table("pipedream", 4, 8)


# --------------------------------------------------------------- ParallelPlan
def test_parallel_plan_validation():
    from repro.configs import SURVEY_DEMO, reduced
    from repro.core.partitioner import ParallelPlan, auto_plan

    cfg = reduced(SURVEY_DEMO, n_layers=4, n_heads=4, n_kv_heads=2, d_ff=256)
    ParallelPlan(dp=2, tp=2, pp=2, microbatches=4).validate(cfg)
    with pytest.raises(ValueError):  # async rows are simulator-only
        ParallelPlan(pp=2, schedule="pipedream").validate(cfg)
    with pytest.raises(ValueError):  # 4 layers don't split into 3 stages
        ParallelPlan(pp=3, microbatches=2).validate(cfg)
    with pytest.raises(ValueError):  # kv heads not divisible by tp
        ParallelPlan(tp=4, microbatches=2).validate(cfg)
    with pytest.raises(ValueError):  # MoE composes with EP, not manual TP
        moe = reduced(SURVEY_DEMO, n_layers=4, n_heads=4, n_kv_heads=2,
                      d_ff=256, ffn_kind="moe", n_experts=4, experts_top_k=2)
        ParallelPlan(tp=2, microbatches=2).validate(moe)


def test_auto_plan_respects_batch_cap():
    """With dp capped by the batch, spare devices go to the pipeline."""
    from repro.configs import SURVEY_DEMO, reduced
    from repro.core.partitioner import auto_plan

    cfg = reduced(SURVEY_DEMO, n_layers=8, n_heads=4, n_kv_heads=2, d_ff=256)
    free = auto_plan(cfg, 8, microbatches=4)
    assert (free.dp, free.pp) == (8, 1)      # perfect-DP model: dp wins
    capped = auto_plan(cfg, 8, microbatches=4, max_dp=4)
    assert capped.pp > 1 and capped.dp <= 4
    assert capped.n_devices == 8
    # boundaries are uniform (executable constraint)
    b = capped.stage_boundaries(cfg.n_layers)
    sizes = {b[i + 1] - b[i] for i in range(len(b) - 1)}
    assert len(sizes) == 1


RUNNER_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.pipeline import pipeline_apply

    P, M, D = 4, 8, 16
    mesh = jax.make_mesh((4,), ("pipe",))
    rng = np.random.RandomState(0)
    stage_params = {"w": jnp.asarray(rng.randn(P, D, D) * 0.3, jnp.float32),
                    "b": jnp.asarray(rng.randn(P, D) * 0.1, jnp.float32)}
    mbs = jnp.asarray(rng.randn(M, 2, D), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    out = pipeline_apply(stage_fn, stage_params, mbs, mesh=mesh)

    # sequential reference
    ref = mbs
    for s in range(P):
        ps = {k: v[s] for k, v in stage_params.items()}
        ref = jax.vmap(lambda x: stage_fn(ps, x))(ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)

    # gradients flow through the pipeline (AD-reversed schedule)
    def loss(sp):
        y = pipeline_apply(stage_fn, sp, mbs, mesh=mesh)
        return jnp.mean(y ** 2)

    def loss_ref(sp):
        r = mbs
        for s in range(P):
            ps = {k: v[s] for k, v in sp.items()}
            r = jax.vmap(lambda x: stage_fn(ps, x))(r)
        return jnp.mean(r ** 2)

    g = jax.grad(loss)(stage_params)
    gr = jax.grad(loss_ref)(stage_params)
    for k in g:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(gr[k]),
                                   rtol=1e-4, atol=1e-5)
    print("PIPELINE_OK")
    """
)


@pytest.mark.multidevice
def test_executable_gpipe_matches_sequential():
    r = subprocess.run(
        [sys.executable, "-c", RUNNER_SCRIPT],
        capture_output=True, text=True, timeout=300,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPELINE_OK" in r.stdout
