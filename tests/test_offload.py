"""Offload planners (survey §2.2, Table 3)."""
from _hyp_compat import hypothesis, st
import pytest

from repro.core.offload import (
    ACTION_KEEP,
    ACTION_OFFLOAD,
    LinkModel,
    dynprog_joint,
    greedy_planner,
    lifetime_planner,
    simulate_schedule,
)

FAST_LINK = LinkModel(bandwidth=1e12, latency=0.0)
SLOW_LINK = LinkModel(bandwidth=1.0, latency=0.0)  # 1 byte/s: transfers hurt


def test_keep_everything_baseline():
    t = [1.0] * 8
    a = [1.0] * 8
    est, peak = simulate_schedule(t, a, [ACTION_KEEP] * 8, FAST_LINK)
    assert peak == 8.0
    assert est == pytest.approx(sum(t) * 3)  # fwd + 2x bwd


def test_offload_cuts_peak_fast_link_free():
    t = [1.0] * 8
    a = [1.0] * 8
    actions = [ACTION_OFFLOAD] * 4 + [ACTION_KEEP] * 4
    est, peak = simulate_schedule(t, a, actions, FAST_LINK)
    base_est, base_peak = simulate_schedule(t, a, [ACTION_KEEP] * 8, FAST_LINK)
    assert peak < base_peak
    assert est == pytest.approx(base_est, rel=1e-6)  # infinite link: free


def test_offload_costs_time_on_slow_link():
    t = [1.0] * 4
    a = [10.0] * 4
    actions = [ACTION_OFFLOAD] * 4
    est, _ = simulate_schedule(t, a, actions, SLOW_LINK)
    base, _ = simulate_schedule(t, a, [ACTION_KEEP] * 4, SLOW_LINK)
    assert est > base  # transfers dominate


@pytest.mark.parametrize("planner", [lifetime_planner, greedy_planner, dynprog_joint])
def test_planners_respect_budget(planner):
    t = [1.0, 2.0, 1.0, 3.0, 1.0, 1.0]
    a = [4.0, 1.0, 2.0, 1.0, 3.0, 1.0]
    budget = 6.0
    plan = planner(t, a, budget, LinkModel(bandwidth=10.0))
    assert plan.peak_memory <= budget + 1e-9, plan


def test_dynprog_no_worse_than_heuristics():
    t = [1.0, 2.0, 1.0, 3.0, 1.0, 1.0]
    a = [4.0, 1.0, 2.0, 1.0, 3.0, 1.0]
    budget = 6.0
    link = LinkModel(bandwidth=10.0)
    dp = dynprog_joint(t, a, budget, link)
    for h in (lifetime_planner(t, a, budget, link), greedy_planner(t, a, budget, link)):
        if h.peak_memory <= budget:
            assert dp.est_time <= h.est_time + 1e-9


@hypothesis.given(st.integers(2, 8), st.integers(0, 50))
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_planner_feasible_or_fallback(n, seed):
    import random

    rng = random.Random(seed)
    t = [0.5 + rng.random() for _ in range(n)]
    a = [0.5 + 2 * rng.random() for _ in range(n)]
    budget = max(a) + 0.5  # very tight but feasible via recompute-all
    plan = dynprog_joint(t, a, budget, LinkModel(bandwidth=5.0))
    est, peak = simulate_schedule(t, a, plan.actions, LinkModel(bandwidth=5.0))
    assert est == pytest.approx(plan.est_time)
