"""End-to-end training loop tests: loss decreases; features compose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SURVEY_DEMO, get_reduced, reduced
from repro.core.compression import QSGD, TopK
from repro.data import DataPipeline
from repro.optim import get as get_opt
from repro.train import TrainConfig, fit, make_state, make_train_step

TINY = reduced(SURVEY_DEMO, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
               d_ff=256, vocab_size=512)


def run(tc: TrainConfig, steps=30, seed=0, opt_name=None, lr=1e-3):
    opt = get_opt(opt_name or tc.optimizer, lr)
    data = DataPipeline(TINY, batch_size=8, seq_len=64, seed=seed)
    try:
        state, hist = fit(TINY, tc, data, steps, opt, log=lambda s: None)
    finally:
        data.close()
    return hist


def losses(hist):
    return [h["loss"] for h in hist]


def test_loss_decreases_baseline():
    hist = run(TrainConfig(log_every=5), steps=40)
    ls = losses(hist)
    assert ls[-1] < ls[0] - 0.5, ls


def test_remat_full_same_trajectory():
    """Remat changes memory, not math: losses must match step-for-step."""
    h1 = run(TrainConfig(log_every=5, remat="none"), steps=15)
    h2 = run(TrainConfig(log_every=5, remat="full"), steps=15)
    np.testing.assert_allclose(losses(h1), losses(h2), rtol=1e-4)


def test_remat_dots_same_trajectory():
    h1 = run(TrainConfig(log_every=5, remat="none"), steps=10)
    h2 = run(TrainConfig(log_every=5, remat="dots"), steps=10)
    np.testing.assert_allclose(losses(h1), losses(h2), rtol=1e-4)


def test_remat_offload_same_trajectory():
    """Host-offload remat (activations to pinned_host) is math-identical."""
    h1 = run(TrainConfig(log_every=5, remat="none"), steps=8)
    h2 = run(TrainConfig(log_every=5, remat="offload"), steps=8)
    np.testing.assert_allclose(losses(h1), losses(h2), rtol=1e-4)


def test_bf16_trains():
    hist = run(TrainConfig(log_every=5, precision="bf16"), steps=40)
    ls = losses(hist)
    assert ls[-1] < ls[0] - 0.4, ls


def test_fp16_loss_scaling_trains():
    hist = run(TrainConfig(log_every=5, precision="fp16"), steps=40)
    ls = losses(hist)
    assert ls[-1] < ls[0] - 0.4, ls


def test_compressed_loopback_trains():
    hist = run(TrainConfig(log_every=5, compression=TopK(0.1)), steps=50)
    ls = losses(hist)
    assert ls[-1] < ls[0] - 0.3, ls


def test_qsgd_trains_like_dense():
    dense = losses(run(TrainConfig(log_every=5), steps=30))
    q = losses(run(TrainConfig(log_every=5, compression=QSGD(8)), steps=30))
    assert q[-1] < dense[-1] + 0.3


def test_adam8bit_trains():
    hist = run(TrainConfig(log_every=5, optimizer="adam8bit"), steps=30)
    ls = losses(hist)
    assert ls[-1] < ls[0] - 0.3, ls


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore, save

    opt = get_opt("adamw", 1e-3)
    tc = TrainConfig()
    state = make_state(TINY, opt, tc, seed=3)
    save(str(tmp_path), 7, state)
    template = make_state(TINY, opt, tc, seed=9)
    restored = restore(str(tmp_path), template)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_partial_restore(tmp_path):
    from repro.checkpoint import restore, save

    opt = get_opt("adamw", 1e-3)
    tc = TrainConfig()
    state = make_state(TINY, opt, tc, seed=3)
    save(str(tmp_path), 1, state)
    template = make_state(TINY, opt, tc, seed=9)
    restored = restore(str(tmp_path), template, subset="params")
    # params match saved, opt state keeps template
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(restored["params"])[0]),
        np.asarray(jax.tree.leaves(state["params"])[0]),
    )


def test_data_pipeline_deterministic_and_sharded():
    d1 = DataPipeline(TINY, 4, 32, seed=1, shard=(0, 2))
    d2 = DataPipeline(TINY, 4, 32, seed=1, shard=(1, 2))
    try:
        b1, b2 = next(d1), next(d2)
        assert b1["tokens"].shape == (4, 32)
        assert not np.array_equal(b1["tokens"], b2["tokens"])  # disjoint shards
        assert (b1["tokens"] < TINY.vocab_size).all()
    finally:
        d1.close()
        d2.close()
